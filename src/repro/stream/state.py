"""Incremental LAF-DBSCAN cluster state.

The batch engines recompute the whole eps-graph per run; this module
keeps just enough state to maintain the *same partition* online:

* exact per-point neighbor counts (``counts``) — for points whose range
  query was executed; a lower bound for skipped (predicted-stop) points,
  mirroring the paper's partial-neighbor map |𝓔| semantics;
* the core mask and a growable :class:`~repro.core.union_find.UnionFind`
  over the core-core eps-graph;
* per-point border ownership (``owner``) — the **minimum-index core
  neighbor**, which is exactly the "first core finder" rule both batch
  engines implement (they scan core rows in ascending index order), so
  streaming labels match a from-scratch run point for point, not just
  up to border ties.

Correctness invariant (why one pass per batch suffices): every eps-pair
is observed exactly once, by the *later* arrival's range query (new
rows query old + new); a pair between two old points was observed when
the younger of them arrived.  Core-core union edges are therefore
closed under three events — a new core's own row, an old point whose
count crosses tau (``promote`` re-queries it against everything), and
nothing else — because an edge between two points that were both
already core was unioned when the younger one arrived or promoted.

Deletion is the hard direction (union-find cannot split): ``evict``
tombstones rows and decrements neighbor counts, and reports whether the
removal demoted a core point or killed one — the caller (the ingest
driver) must rebuild then.  That asymmetry is inherent to density
clustering, not an implementation shortcut (cf. streaming metric-DBSCAN
literature: inserts are cheap, deletes force re-verification).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.range_query import pack_bitmap
from ..core.union_find import UnionFind, compact_labels_from_parent, union_star

__all__ = ["StreamingClusterState"]


def _grow_to(arr: np.ndarray, n: int, fill) -> np.ndarray:
    """Amortized-doubling growth of a 1-d state array to >= n entries."""
    if arr.shape[0] >= n:
        return arr
    cap = max(2 * arr.shape[0], n, 64)
    out = np.full(cap, fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


class StreamingClusterState:
    """Cluster bookkeeping for one (eps, tau) operating point.

    The driver (``repro.stream.ingest``) owns the range-query backend
    and feeds hit rows in; this class never touches vectors.  All hit
    rows handed in are boolean over the *current* ``n`` points and are
    masked by ``alive`` internally, so tombstoned rows neither count nor
    union.
    """

    def __init__(self, eps: float, tau: int):
        self.eps = float(eps)
        self.tau = int(tau)
        self.n = 0
        self.counts = np.zeros(0, dtype=np.int64)
        self.core = np.zeros(0, dtype=bool)
        self.alive = np.zeros(0, dtype=bool)
        self.queried = np.zeros(0, dtype=bool)  # False => counts is a lower bound
        self.owner = np.full(0, -1, dtype=np.int64)  # min-index core neighbor
        self.uf = UnionFind(0)
        self.version = 0  # bumped per mutation epoch; serving snapshots key on it

    # -- growth ------------------------------------------------------------
    def extend(self, k: int) -> np.ndarray:
        """Register k new points; returns their (contiguous) indices."""
        new = np.arange(self.n, self.n + k, dtype=np.int64)
        self.n += k
        self.counts = _grow_to(self.counts, self.n, 0)
        self.core = _grow_to(self.core, self.n, False)
        self.alive = _grow_to(self.alive, self.n, False)
        self.queried = _grow_to(self.queried, self.n, False)
        self.owner = _grow_to(self.owner, self.n, -1)
        self.alive[new] = True
        self.uf.grow(self.n)
        self.version += 1
        return new

    # -- per-batch updates (driven by ingest) ------------------------------
    def _masked(self, hit: np.ndarray) -> np.ndarray:
        return hit & self.alive[: hit.shape[1]][None, :]

    def ingest_rows(
        self, rows: np.ndarray, hit: np.ndarray, exclude: Optional[np.ndarray] = None
    ) -> None:
        """Count update for newly added, *executed* rows.

        ``hit`` is (len(rows), n) — each row's complete adjacency against
        every current point (old + this batch + itself).  Own counts are
        the row sums; every other point's count is bumped by the
        transposed hits, **except** the points in ``exclude`` — the whole
        batch's executed set (defaults to ``rows``).  Each eps-pair must
        land exactly once per endpoint: an executed point's count comes
        from its own complete row, so a bump from a *same-batch* peer's
        row (possibly processed in a different block) would double-count
        the pair; callers chunking one batch over several calls must
        pass the full executed set.
        """
        hit = self._masked(hit)
        self.counts[rows] = hit.sum(axis=1, dtype=np.int64)
        self.queried[rows] = True
        bump = hit.sum(axis=0, dtype=np.int64)
        bump[rows if exclude is None else exclude] = 0
        self.counts[: len(bump)] += bump

    def seed_skipped(self, rows: np.ndarray, core_idx: np.ndarray, hit_cores: np.ndarray) -> None:
        """Count lower bound + ownership for skipped (predicted-stop) rows.

        ``hit_cores`` is (len(rows), len(core_idx)) against the current
        core set only — the online analog of the paper's map 𝓔: a
        skipped point accrues neighbors only from core/executed queries,
        never pays a full range query, and promotes through
        ``promote`` if its lower bound crosses tau.  Nothing is bumped
        transposed (core points are already core; non-core old points
        keep the executed-only semantics of |𝓔|).
        """
        if len(core_idx) == 0:
            self.counts[rows] = 0
            return
        self.counts[rows] = hit_cores.sum(axis=1, dtype=np.int64)
        any_hit = hit_cores.any(axis=1)
        first = core_idx[hit_cores.argmax(axis=1)]  # min core idx (core_idx sorted)
        self.owner[rows[any_hit]] = first[any_hit]

    def take_promotions(self) -> np.ndarray:
        """Alive non-core points whose count has crossed tau.

        Marks them core immediately (so the promotion re-queries union
        promoted-promoted edges) and returns their indices; the driver
        must follow up with ``promote`` rows for each.
        """
        idx = np.nonzero(self.alive & ~self.core & (self.counts >= self.tau))[0]
        self.core[idx] = True
        return idx

    def promote(self, rows: np.ndarray, hit: np.ndarray) -> None:
        """Full re-query rows of freshly promoted points.

        Sets their exact counts (the re-query sees everything, including
        points their lower bound missed), unions them with every core
        neighbor, and claims their non-core neighbors — **without**
        bumping anyone else's count: every pair in these rows was either
        already counted by the younger endpoint's arrival or is
        deliberately excluded by the skip semantics.
        """
        hit = self._masked(hit)
        self.counts[rows] = hit.sum(axis=1, dtype=np.int64)
        self.queried[rows] = True
        self.apply_core_rows(rows, hit)

    def promote_packed(self, rows: np.ndarray, pk: np.ndarray) -> None:
        """``promote`` on a packed re-query block (counts by popcount,
        connectivity via ``apply_core_rows_packed``)."""
        n = self.n
        pk = pk[:, : (n + 31) // 32] & pack_bitmap(self.alive[:n][None, :])
        self.counts[rows] = np.bitwise_count(pk).sum(axis=1, dtype=np.int64)
        self.queried[rows] = True
        self.apply_core_rows_packed(rows, pk)

    def apply_core_rows(self, rows: np.ndarray, hit: np.ndarray) -> None:
        """Union + ownership from the hit rows of core points.

        For each core row r: star-union {r} ∪ (N(r) ∩ core), and offer r
        as owner to its non-core neighbors (min-index rule).  Rows that
        are not core only pick up their own ownership (their core
        neighbors are in their row).
        """
        hit = self._masked(hit)
        core = self.core[: hit.shape[1]]
        hit_core = hit & core[None, :]
        row_core = self.core[rows]
        for bi in np.nonzero(row_core)[0]:
            union_star(self.uf.parent, np.nonzero(hit_core[bi])[0])
        # ownership offers: min over {core rows in this block} ∪ {min
        # core neighbor in each non-core row's own adjacency}
        sub = hit[row_core]
        if sub.shape[0]:
            subrows = rows[row_core]
            claimed = sub.any(axis=0)
            cand = claimed & ~core
            if cand.any():
                first = subrows[sub[:, cand].argmax(axis=0)]
                # subrows ascend, but keep an explicit min for safety
                cur = self.owner[: hit.shape[1]][cand]
                best = np.where((cur < 0) | (first < cur), first, cur)
                self.owner[np.nonzero(cand)[0]] = best
        nc = ~row_core
        if nc.any():
            ncrows = rows[nc]
            own_core = hit_core[nc]
            any_hit = own_core.any(axis=1)
            first = own_core.argmax(axis=1)
            cur = self.owner[ncrows]
            best = np.where(any_hit & ((cur < 0) | (first < cur)), first, cur)
            self.owner[ncrows] = best
        self.version += 1

    def apply_core_rows_packed(self, rows: np.ndarray, pk: np.ndarray) -> None:
        """``apply_core_rows`` on a *packed* hit block, never unpacked.

        ``pk`` is the (len(rows), ceil(n/32)) uint32 bitmap of the same
        rows ``apply_core_rows`` takes boolean.  The block goes through
        the bipartite label-propagation program
        (:func:`repro.kernels.label_prop.packed_connectivity`) and only
        three small s32 vectors come back: per-column component
        representative (the transitive closure of the per-row star
        unions), per-column min core row (ownership offers), and
        per-row min core column (non-core rows' own ownership).  The
        union-find and owner updates they drive are identical to the
        unpacked pass.
        """
        import jax

        from ..kernels.label_prop import packed_connectivity

        n = self.n
        rows = np.asarray(rows, dtype=np.int64)
        # alive masking happens in packed space (the _masked analog);
        # the slice drops capacity-padding words a device slab may
        # carry and the mask's own zero tail clears bits past n
        pk = pk[:, : (n + 31) // 32] & pack_bitmap(self.alive[:n][None, :])
        row_core = self.core[rows]
        comp, owner, row_first, _ = jax.device_get(
            packed_connectivity(pk, rows, row_core, self.core[:n])
        )
        big = np.iinfo(np.int32).max
        # star-union each component (only columns adjacent to a core
        # block row participate; everything else kept its own label)
        sel = np.nonzero(self.core[:n] & (owner != big))[0]
        if sel.size:
            order = np.argsort(comp[sel], kind="stable")
            sel = sel[order]
            _, starts = np.unique(comp[sel], return_index=True)
            for grp in np.split(sel, starts[1:]):
                union_star(self.uf.parent, grp)
        # ownership offers from the block's core rows
        cand = (~self.core[:n]) & (owner != big)
        if cand.any():
            first = owner[cand].astype(np.int64)
            cur = self.owner[:n][cand]
            best = np.where((cur < 0) | (first < cur), first, cur)
            self.owner[np.nonzero(cand)[0]] = best
        # non-core rows pick up their own ownership
        nc = ~row_core
        if nc.any():
            ncrows = rows[nc]
            first = row_first[nc].astype(np.int64)
            any_hit = first < big
            cur = self.owner[ncrows]
            best = np.where(any_hit & ((cur < 0) | (first < cur)), first, cur)
            self.owner[ncrows] = best
        self.version += 1

    # -- deletion ----------------------------------------------------------
    def evict(self, rows: np.ndarray, hit: np.ndarray) -> bool:
        """Tombstone rows; returns True when a rebuild is required.

        ``hit`` is the evicted rows' adjacency against all current
        points (queried *before* tombstoning).  Counts of surviving
        points are decremented so future promotions stay sound.  A
        rebuild is required when the eviction kills a core point or
        demotes one (union-find cannot split) — the driver handles it.
        """
        rows = np.asarray(rows, dtype=np.int64)
        rows, first = np.unique(rows, return_index=True)  # dedupe: a repeated
        hit = hit[first]                                  # index must decrement once
        live = self.alive[rows]
        rows, hit = rows[live], hit[live]  # drop already-dead rows *and*
        if len(rows) == 0:                 # their hit rows, else survivors
            return False                   # get decremented twice
        killed_core = bool(self.core[rows].any())
        hit = self._masked(hit)
        dec = hit.sum(axis=0, dtype=np.int64)
        dec[rows] = 0
        self.alive[rows] = False
        self.counts[: len(dec)] -= dec
        demoted = self.alive[: self.n] & self.core[: self.n] & (
            self.counts[: self.n] < self.tau
        )
        self.version += 1
        return killed_core or bool(demoted.any())

    @property
    def n_dead(self) -> int:
        return int(self.n - self.alive[: self.n].sum())

    # -- durability --------------------------------------------------------
    def export_arrays(self) -> dict:
        """Snapshot as a flat dict of host arrays (capacity-faithful:
        the doubling-grown state arrays and the union-find's parent/size
        are exported whole, so a restored replica re-enters the same
        amortized-growth schedule it crashed out of)."""
        return {
            "eps": np.float64(self.eps),
            "tau": np.int64(self.tau),
            "n": np.int64(self.n),
            "version": np.int64(self.version),
            "counts": self.counts.copy(),
            "core": self.core.copy(),
            "alive": self.alive.copy(),
            "queried": self.queried.copy(),
            "owner": self.owner.copy(),
            "uf_parent": self.uf.parent[: self.n].copy(),
            "uf_size": self.uf.size[: self.n].copy(),
        }

    @classmethod
    def import_arrays(cls, state: dict) -> "StreamingClusterState":
        """Rebuild from an ``export_arrays`` dict (bit-identical labels/
        owners/counts — the kill-restore parity contract)."""
        self = cls(float(state["eps"]), int(state["tau"]))
        self.n = int(state["n"])
        self.version = int(state["version"])
        self.counts = np.ascontiguousarray(state["counts"], dtype=np.int64)
        self.core = np.ascontiguousarray(state["core"], dtype=bool)
        self.alive = np.ascontiguousarray(state["alive"], dtype=bool)
        self.queried = np.ascontiguousarray(state["queried"], dtype=bool)
        self.owner = np.ascontiguousarray(state["owner"], dtype=np.int64)
        self.uf = UnionFind(self.n)
        self.uf.parent[: self.n] = state["uf_parent"]
        self.uf.size[: self.n] = state["uf_size"]
        return self

    # -- extraction --------------------------------------------------------
    def labels(self) -> np.ndarray:
        """(n,) labels: -1 noise/dead, clusters 0..k-1 (compacted by
        smallest member, the batch engines' convention)."""
        active = self.core[: self.n] & self.alive[: self.n]
        labels = compact_labels_from_parent(self.uf.parent[: self.n].copy(), active)
        border = self.alive[: self.n] & ~self.core[: self.n] & (self.owner[: self.n] >= 0)
        bidx = np.nonzero(border)[0]
        if len(bidx):
            owners = self.owner[bidx]
            ok = self.alive[owners] & self.core[owners]
            labels[bidx[ok]] = labels[owners[ok]]
        return labels

    @property
    def n_clusters(self) -> int:
        labels = self.labels()
        return int(labels.max()) + 1 if labels.size and labels.max() >= 0 else 0
