"""Streaming LAF-DBSCAN: the batch ingest driver.

``StreamingLAF`` owns a range-query backend (``repro.index``) and a
:class:`~repro.stream.state.StreamingClusterState`, and turns embedding
batches into maintained clusters:

1. ``backend.partial_fit(batch)`` appends the rows + packed signatures
   (amortized doubling — no index rebuild);
2. **only the new rows** are ranged against the database (new-vs-all
   through the fused tile / host band evaluator); old points' counts are
   bumped from the transposed hits, so a point crossing tau *promotes*
   to core and merges clusters without recomputing a single old edge;
3. optional learned-estimator fast path: new rows predicted below
   ``alpha * tau`` skip their full range query (they are verified
   against the current core set only — the online analog of the paper's
   partial-neighbor map 𝓔) and promote later if their partial count
   crosses tau;
4. a ``decay`` hook can evict rows per batch; deletions that demote or
   kill a core point trigger a rebuild (union-find cannot split).

With the estimator disabled the maintained partition is **identical**
to a from-scratch batch run on the accumulated data (same counts, same
core set, same core-graph components, same min-core-neighbor border
rule) — see ``tests/test_stream.py`` for the ARI == 1.0 parity checks.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from ..configs.laf_dbscan import StreamConfig
from ..core.range_query import pack_bitmap, unpack_bitmap
from ..index import make_backend
from ..obs import get_logger, metrics as _metrics, rate_limited_warn, slo as _slo, span as _span
from .state import StreamingClusterState

__all__ = ["StreamingLAF", "IngestReport"]


@dataclass
class IngestReport:
    """Per-batch accounting (the streaming analog of ``DBSCANResult.extras``)."""

    n_new: int
    n_executed: int          # new rows that paid a full range query
    n_skipped: int           # new rows on the estimator fast path
    n_promoted: int          # old/skipped points that crossed tau this batch
    n_points: int            # database size after the batch
    n_clusters: int
    elapsed_s: float
    rebuilt: bool = False
    extras: dict = field(default_factory=dict)


class StreamingLAF:
    """Incremental LAF-DBSCAN over an append-mostly embedding stream.

    Args:
      eps, tau: the DBSCAN operating point (fixed per stream — the
        maintained counts are eps-specific).
      backend: ``repro.index`` spec — a registry name (fresh instance)
        or a constructed ``RangeBackend`` (which keeps its own index
        configuration — passing extra index kwargs alongside one is an
        error).  A *pre-fitted* instance warm-starts the stream: its
        rows are absorbed as batch zero, so ``fit`` offline then stream
        online just works.  ``partial_fit`` must append without moving
        existing row indices (all shipped backends do).
      estimator: optional cardinality estimator for the ingest fast
        path — either a ``TrainedEstimator`` (``predict_counts(v, eps)``)
        or any callable ``(vectors) -> predicted_counts``.
      config: a :class:`repro.configs.laf_dbscan.StreamConfig` supplying
        defaults for the remaining knobs; explicit kwargs win.
      decay: optional per-batch eviction hook ``(state) -> indices`` —
        whatever it returns is evicted after the batch is absorbed.
    """

    def __init__(
        self,
        eps: float,
        tau: int,
        *,
        backend="random_projection",
        device=None,
        estimator=None,
        config: Optional[StreamConfig] = None,
        alpha: Optional[float] = None,
        use_estimator: Optional[bool] = None,
        block_size: Optional[int] = None,
        decay: Optional[Callable] = None,
        max_dead_frac: Optional[float] = None,
        **backend_kwargs,
    ):
        cfg = config or StreamConfig()
        self.eps = float(eps)
        self.tau = int(tau)
        self.alpha = cfg.alpha if alpha is None else float(alpha)
        self.use_estimator = (
            cfg.use_estimator if use_estimator is None else bool(use_estimator)
        )
        self.block_size = cfg.batch_rows if block_size is None else block_size
        self.decay = decay
        self.max_dead_frac = cfg.max_dead_frac if max_dead_frac is None else max_dead_frac
        self.config = cfg
        self.estimator = estimator
        from ..index.base import RangeBackend

        if isinstance(backend, RangeBackend):
            # an instance keeps its own configuration (make_backend's
            # passthrough) — silently dropping these would mean serving
            # on a different index than the caller specified
            dropped = sorted(backend_kwargs) + (["device"] if device is not None else [])
            if dropped:
                raise ValueError(
                    f"backend is a constructed instance; index kwargs {dropped} "
                    f"would be ignored — configure the instance instead, or "
                    f"pass the registry name"
                )
        self.backend = make_backend(
            backend,
            block_size=self.block_size,
            device="auto" if device is None else device,
            **backend_kwargs,
        )
        self.state = StreamingClusterState(eps, tau)
        self._serve = None  # ClusterIndex snapshot, keyed on state.version
        if getattr(self.backend, "_data", None) is not None and self.backend.n_points:
            # warm start from a pre-fitted index: absorb its rows into
            # the cluster state so state indices stay aligned with
            # backend rows (fit offline, stream online)
            self._absorb(np.ascontiguousarray(self.backend.data))

    # -- estimator glue ----------------------------------------------------
    def _predict(self, vectors: np.ndarray) -> Optional[np.ndarray]:
        if self.estimator is None or not self.use_estimator:
            return None
        if hasattr(self.estimator, "predict_counts"):
            return np.asarray(self.estimator.predict_counts(vectors, self.eps))
        return np.asarray(self.estimator(vectors))

    # -- ingest ------------------------------------------------------------
    def partial_fit(self, batch: np.ndarray) -> IngestReport:
        """Absorb one embedding batch; returns the batch report."""
        batch = np.ascontiguousarray(batch, dtype=np.float32)
        if batch.ndim != 2 or batch.shape[0] == 0:
            raise ValueError(f"batch must be (rows, d) with rows >= 1, got {batch.shape}")
        # forced span: the append dispatches async device work (donated
        # capacity buffers), so the reported batch time must sync on the
        # backend's device state, not read a bare wall clock
        with _span("ingest.batch", rows=batch.shape[0], n=self.state.n,
                   force=True) as batch_sp:
            with _span("ingest.append", rows=batch.shape[0]):
                self.backend.partial_fit(batch)
            rep = self._absorb(batch)
            rebuilt = False
            if self.decay is not None:
                idx = self.decay(self.state)
                if idx is not None and len(idx):
                    rebuilt = self.evict(idx)
            batch_sp.sync_on(tuple(
                getattr(self.backend, a, None)
                for a in ("_sigs_dev", "_data_dev", "_sweep_dev", "_host_sigs_dev")
            ))
        rep.rebuilt = rebuilt
        rep.elapsed_s = batch_sp.dur
        # refresh state-derived fields after the decay hook: an eviction
        # (or rebuild) changes the database the report describes
        rep.n_points = self.state.n
        rep.n_clusters = self.state.n_clusters
        if _metrics.enabled():
            # per-batch SLO sweep with the batch's derived skip rate —
            # violations surface as rate-limited slo.violation lines
            _slo.check_and_alert(
                _slo.INGEST_SLOS,
                values={"ingest.skip_rate": rep.n_skipped / max(rep.n_new, 1)},
            )
        return rep

    def _absorb(self, batch: np.ndarray) -> IngestReport:
        """Cluster-maintenance pass for rows the backend already holds."""
        state, bk, eps = self.state, self.backend, self.eps
        pre_core = np.nonzero(state.core[: state.n] & state.alive[: state.n])[0]
        new_idx = state.extend(batch.shape[0])

        pred = self._predict(batch)
        exec_mask = (
            np.ones(len(new_idx), dtype=bool)
            if pred is None
            else pred >= self.alpha * self.tau
        )
        skip_idx = new_idx[~exec_mask]
        _metrics.counter("stream.ingest.skipped").inc(int(len(skip_idx)))
        if len(skip_idx):
            # fast path: verify skipped rows against the core set only
            # (the online 𝓔 lower bound — O(|cores|) instead of O(n))
            with _span("ingest.fastpath", rows=len(skip_idx), cores=len(pre_core)):
                hit_cores = (
                    bk.query_hits_subset(skip_idx, pre_core, eps)
                    if len(pre_core)
                    else np.zeros((len(skip_idx), 0), dtype=bool)
                )
                state.seed_skipped(skip_idx, pre_core, hit_cores)

        exec_idx = new_idx[exec_mask]
        _metrics.counter("stream.ingest.executed").inc(int(len(exec_idx)))
        packed: list[tuple[np.ndarray, np.ndarray]] = []
        native = getattr(bk, "packs_natively", False)
        with _span("ingest.sweep", rows=len(exec_idx), native=bool(native)):
            for start in range(0, len(exec_idx), self.block_size):
                rows = exec_idx[start : start + self.block_size]
                # replay storage keeps adjacency packed; the sweep engine
                # emits packed words natively (one launch per block, one
                # host sync), so on that path only the ingest-side unpack
                # is paid — host backends keep the boolean-first order so
                # they never pay an unpack→repack round-trip
                if native:
                    _, pk = bk.query_hits_packed(rows, eps)
                    hit = unpack_bitmap(pk, state.n)
                else:
                    hit = bk.query_hits(rows, eps)
                    pk = pack_bitmap(hit)
                # exclude the whole executed set from the transposed bumps:
                # a same-batch pair split across two blocks would otherwise
                # double-count for the earlier block's endpoint
                state.ingest_rows(rows, hit, exclude=exec_idx)
                packed.append((rows, pk))

        # one promotion round closes the core set: new executed rows are
        # core straight from their counts; old/skipped points crossing
        # tau are re-queried for their exact counts + core-core edges
        promoted = state.take_promotions()
        requery = promoted[~np.isin(promoted, exec_idx, assume_unique=True)]
        _metrics.counter("stream.ingest.promoted").inc(int(len(requery)))
        # skip-rule false negatives the promotion round caught: rows the
        # estimator fast-pathed this batch that turned out core after all
        _metrics.counter("stream.ingest.skipped_promoted").inc(
            int(np.isin(requery, skip_idx, assume_unique=True).sum())
        )
        with _span("ingest.promote", rows=len(requery), native=bool(native)):
            for start in range(0, len(requery), self.block_size):
                rows = requery[start : start + self.block_size]
                if native:
                    _, pk = bk.query_hits_packed(rows, eps)
                    state.promote_packed(rows, pk)
                else:
                    state.promote(rows, bk.query_hits(rows, eps))
        # connectivity replay: on the native path each block's packed
        # words go straight through the bipartite label-prop program —
        # adjacency stays packed end-to-end (no per-batch unpack)
        with _span("ingest.apply", blocks=len(packed), native=bool(native)):
            for rows, pk in packed:
                if native:
                    state.apply_core_rows_packed(rows, pk)
                else:
                    state.apply_core_rows(rows, unpack_bitmap(pk, state.n))

        self._serve = None
        return IngestReport(
            n_new=len(new_idx),
            n_executed=len(exec_idx),
            n_skipped=len(skip_idx),
            n_promoted=len(requery),
            n_points=state.n,
            n_clusters=-1,  # filled by partial_fit after decay runs
            elapsed_s=0.0,
        )

    # -- deletion ----------------------------------------------------------
    def evict(self, idx: np.ndarray) -> bool:
        """Tombstone rows; rebuilds when required.  Returns True iff a
        rebuild happened (a core died/demoted, or tombstones piled past
        ``max_dead_frac``)."""
        idx = np.asarray(idx, dtype=np.int64)
        hit = self.backend.query_hits(idx, self.eps)
        need = self.state.evict(idx, hit)
        state = self.state
        if need or state.n_dead > self.max_dead_frac * max(state.n, 1):
            self.rebuild(reason="core_death" if need else "tombstone_frac")
            return True
        self._serve = None
        return False

    def rebuild(self, reason: str = "manual") -> None:
        """Compact tombstones away: refit the backend on the live rows
        and replay them through the exact ingest path in one batch.
        O(n_live^2) — the price of deletions in density clustering; the
        driver amortizes it behind ``max_dead_frac``.  Every rebuild is
        visible: ``stream.rebuilds`` counts them and a rate-limited
        structured warn records why (a rebuild storm is exactly the
        degradation ROADMAP item 2b's decremental connectivity fixes)."""
        _metrics.counter("stream.rebuilds").inc()
        _metrics.counter(f"stream.rebuilds.{reason}").inc()
        rate_limited_warn(
            get_logger("stream"), "stream.rebuild", "stream.rebuild",
            reason=reason, n=self.state.n, n_dead=self.state.n_dead,
            version=self.state.version,
        )
        live = np.nonzero(self.state.alive[: self.state.n])[0]
        data = np.ascontiguousarray(self.backend.data[live])
        self.backend.fit(data)
        self.state = StreamingClusterState(self.eps, self.tau)
        self._serve = None
        if len(data):
            est, self.use_estimator = self.use_estimator, False
            try:
                self._absorb(data)
            finally:
                self.use_estimator = est

    # -- serving -----------------------------------------------------------
    def snapshot(self):
        """Current :class:`~repro.stream.serve.ClusterIndex` (cached per
        state version — ingest invalidates it)."""
        from .serve import ClusterIndex

        if self._serve is None or self._serve.version != self.state.version:
            self._serve = ClusterIndex.from_stream(self)
        return self._serve

    def assign(self, queries: np.ndarray, **kw):
        """Serving-grade assignment of unseen vectors — see
        :meth:`repro.stream.serve.ClusterIndex.assign`."""
        kw.setdefault("shortlist", self.config.shortlist)
        kw.setdefault("min_hits", self.config.min_hits)
        return self.snapshot().assign(queries, **kw)

    # -- views -------------------------------------------------------------
    def labels(self) -> np.ndarray:
        return self.state.labels()

    @property
    def n_points(self) -> int:
        return self.state.n

    @property
    def n_clusters(self) -> int:
        return self.state.n_clusters
