"""Serving-grade cluster assignment for unseen vectors.

The clustering engines label points *of the database*; serving needs
the other direction — given live-traffic query embeddings that are not
in the database, which maintained cluster does each belong to, and how
sure are we?  ``ClusterIndex`` is an immutable snapshot built from a
:class:`~repro.stream.ingest.StreamingLAF` (or any labels + data pair):

1. **centroid shortlist** — score the query against the per-cluster
   centroids (one small matmul) and expand only the best ``shortlist``
   clusters, the retrieval trick ``examples/recsys_serving.py`` serves;
2. **band-verified range query** — inside the shortlist, candidates are
   pruned with the same signed-RP Hamming band the index uses (signature
   XOR+popcount, sure-accept below ``t_lo``, exact dot only for the
   band), so per-query cost is |shortlist members| signature words plus
   a handful of dots — never an O(n·d) scan.  On device this runs
   through the shared sweep engine (``repro.index.sweep``): a block of
   queries is verified against the union of its shortlisted clusters'
   members in **one** launch (``device="auto"`` routes through it
   whenever a real accelerator backs JAX; the host numpy band loop is
   retained as the oracle);
3. **assignment** — the query joins the cluster holding the plurality
   of its eps-neighbors (DBSCAN's border rule, generalized to ties);
   confidence is the fraction of its found eps-neighbors in that
   cluster.  No eps-neighbor in the shortlist => noise (-1), confidence
   0 — exactly how DBSCAN treats a point no core reaches.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..index.signatures import band_hits, hamming_numpy, sign_signatures
from ..obs import metrics as _metrics, slo as _slo, span as _span

__all__ = ["AssignResult", "ClusterIndex", "bucket_shape"]


def bucket_shape(
    n_cand: int, n_block: int, *, db_tile: int = 256, chunk: int = 256,
    q_tile: int = 128,
) -> tuple[int, int]:
    """Quantized ``(db_bucket, query_chunk)`` launch shape for one serve
    verification block.

    The candidate side rounds up to a power of two no smaller than the
    kernel db tile and the query chunk clamps to the power-of-two block
    size (floored at one q tile), so the jitted engine compiles O(log n)
    distinct shapes over any traffic mix — the compile lattice
    ``repro.analysis``'s recompile check enumerates is exactly this
    function's image."""
    bucket = max(db_tile, 1 << int(np.ceil(np.log2(max(n_cand, 1)))))
    chunk = min(chunk, max(q_tile, 1 << int(np.ceil(np.log2(max(n_block, 1))))))
    return bucket, chunk


@dataclass
class AssignResult:
    labels: np.ndarray       # (q,) int64: cluster id or -1 (noise/unmatched)
    confidence: np.ndarray   # (q,) float32 in [0, 1]
    n_hits: np.ndarray       # (q,) int64: eps-neighbors found in the shortlist

    def __len__(self) -> int:
        return len(self.labels)


class ClusterIndex:
    """Immutable serving snapshot: centroids + per-cluster members (+ the
    signature table when the backing index is signed-RP)."""

    def __init__(
        self,
        data: np.ndarray,
        labels: np.ndarray,
        eps: float,
        *,
        sigs: Optional[np.ndarray] = None,
        projection: Optional[np.ndarray] = None,
        band: Optional[tuple[int, int]] = None,
        version: int = 0,
        device="auto",
        sweep_kw: Optional[dict] = None,
        centroids: Optional[np.ndarray] = None,
    ):
        if device not in (True, False, "auto"):
            raise ValueError(f"device must be True, False, or 'auto', got {device!r}")
        self.eps = float(eps)
        self.version = version
        self.device = device
        # kernel/engine knobs (chunk, q_tile, db_tile, interpret, ...)
        # forwarded to repro.index.sweep — from_stream copies them off
        # the backing index so serving verifies on the same evaluator
        self.sweep_kw = dict(sweep_kw or {})
        self._data = data
        self._sigs = sigs
        self._projection = projection
        self._band = band
        labels = np.asarray(labels)
        self.n_clusters = int(labels.max()) + 1 if labels.size and labels.max() >= 0 else 0
        # members grouped by label: one argsort, then slice per cluster
        mask = labels >= 0
        idx = np.nonzero(mask)[0]
        order = np.argsort(labels[idx], kind="stable")
        self._members = idx[order]
        self._offsets = np.searchsorted(labels[idx][order], np.arange(self.n_clusters + 1))
        if centroids is not None and centroids.shape[0] == self.n_clusters:
            # snapshot restore hands the saved centroids back so a
            # replica skips the per-cluster mean pass at build time
            self.centroids = np.ascontiguousarray(centroids, dtype=np.float32)
        else:
            cents = np.zeros((self.n_clusters, data.shape[1]), dtype=np.float32)
            for c in range(self.n_clusters):
                cents[c] = data[self.members(c)].mean(axis=0)
            norms = np.linalg.norm(cents, axis=1, keepdims=True)
            self.centroids = cents / np.maximum(norms, 1e-12)
        # candidate-bucket shapes this snapshot has launched (each new
        # power-of-two bucket is one engine compile — O(log n) total)
        self._seen_buckets: set = set()

    @classmethod
    def from_stream(cls, stream, centroids: Optional[np.ndarray] = None) -> "ClusterIndex":
        bk = stream.backend
        sweep_kw = {
            k: getattr(bk, a)
            for k, a in (
                ("chunk", "chunk"), ("q_tile", "q_tile"), ("db_tile", "db_tile"),
                ("interpret", "interpret"), ("chunks_per_launch", "chunks_per_launch"),
                ("donate", "donate"),
            )
            if hasattr(bk, a)
        }
        return cls(
            bk.data,
            stream.state.labels(),
            stream.eps,
            sigs=getattr(bk, "signatures", None),
            projection=getattr(bk, "projection", None),
            band=bk.band(stream.eps) if hasattr(bk, "band") else None,
            version=stream.state.version,
            device=getattr(bk, "device", "auto"),
            sweep_kw=sweep_kw,
            centroids=centroids,
        )

    def members(self, c: int) -> np.ndarray:
        """Database row indices of cluster ``c``."""
        return self._members[self._offsets[c] : self._offsets[c + 1]]

    def shortlist(self, queries: np.ndarray, k: int) -> np.ndarray:
        """(q, k) best cluster ids by centroid cosine score."""
        q = _unit_rows(queries)
        k = min(k, self.n_clusters)
        scores = q @ self.centroids.T
        top = np.argpartition(-scores, k - 1, axis=1)[:, :k]
        # order the shortlist best-first (argpartition is unordered)
        row = np.arange(len(q))[:, None]
        return top[row, np.argsort(-scores[row, top], axis=1)]

    def assign(
        self, queries: np.ndarray, *, shortlist: int = 8, min_hits: int = 1
    ) -> AssignResult:
        """Cluster ids + confidence for unseen query vectors."""
        queries = np.ascontiguousarray(queries, dtype=np.float32)
        if queries.ndim == 1:
            queries = queries[None, :]
        t0 = time.perf_counter()
        with _span("serve.assign", nq=queries.shape[0], shortlist=shortlist):
            res = self._assign(queries, shortlist=shortlist, min_hits=min_hits)
        if _metrics.enabled():
            # per-call latency into the log-bucket histogram — the p50/
            # p95/p99 the SLO serving roadmap item reports come from here
            _metrics.histogram(
                "serve.assign.latency_s", "assign() wall seconds per call"
            ).observe(time.perf_counter() - t0)
            calls = _metrics.counter("serve.assign.calls")
            calls.inc()
            _metrics.counter("serve.assign.queries").inc(queries.shape[0])
            _metrics.gauge("serve.shortlist").set(min(shortlist, self.n_clusters))
            # periodic SLO sweep: the p99 rule fires (rate-limited) as a
            # structured slo.violation line, never an exception
            if calls.value % _slo.EVAL_EVERY_CALLS == 0:
                _slo.check_and_alert(_slo.SERVE_SLOS)
        return res

    def _assign(
        self, queries: np.ndarray, *, shortlist: int, min_hits: int
    ) -> AssignResult:
        nq = queries.shape[0]
        labels = np.full(nq, -1, dtype=np.int64)
        conf = np.zeros(nq, dtype=np.float32)
        hits_out = np.zeros(nq, dtype=np.int64)
        if self.n_clusters == 0:
            return AssignResult(labels, conf, hits_out)
        q = _unit_rows(queries)
        top = self.shortlist(q, shortlist)
        q_sig = (
            sign_signatures(q, self._projection)
            if self._sigs is not None and self._projection is not None and self._band is not None
            else None
        )
        thresh = 1.0 - self.eps
        cluster_of = np.empty(len(self._data), dtype=np.int64)
        cluster_of[self._members] = np.repeat(
            np.arange(self.n_clusters), np.diff(self._offsets)
        )
        if q_sig is not None and self._use_engine():
            self._assign_engine(
                q, q_sig, top, cluster_of, labels, conf, hits_out, min_hits
            )
            return AssignResult(labels, conf, hits_out)
        for i in range(nq):
            cand = np.concatenate([self.members(c) for c in top[i]])
            if q_sig is not None:
                # the one shared dual-threshold predicate (band_hits):
                # dots are only materialized for the ambiguous band
                t_lo, t_hi = self._band
                ham = hamming_numpy(q_sig[i : i + 1], self._sigs[cand])[0]
                dots = np.zeros(len(cand), dtype=np.float32)
                bi = np.nonzero((ham <= t_hi) & (ham > t_lo))[0]
                if len(bi):
                    dots[bi] = self._data[cand[bi]] @ q[i]
                hit = band_hits(dots, ham, self.eps, t_lo, t_hi)
            else:
                hit = (self._data[cand] @ q[i]) > thresh
            hit_members = cand[hit]
            self._record(
                i, cluster_of[hit_members], labels, conf, hits_out, min_hits
            )
        return AssignResult(labels, conf, hits_out)

    def _record(self, i, hit_clusters, labels, conf, hits_out, min_hits) -> None:
        """Plurality cluster + confidence from one query's eps-neighbor
        cluster ids — the single definition both the host loop and the
        engine path record through, so they stay label-identical."""
        total = len(hit_clusters)
        hits_out[i] = total
        if total < max(min_hits, 1):
            return
        tally = np.bincount(hit_clusters, minlength=self.n_clusters)
        best = int(tally.argmax())
        labels[i] = best
        conf[i] = tally[best] / total

    # -- device-resident assignment (the shared sweep engine) --------------
    def _use_engine(self) -> bool:
        if self.device == "auto":
            from ..kernels.hamming_filter.ops import default_interpret

            return not default_interpret()
        return bool(self.device)

    def _assign_engine(
        self, q, q_sig, top, cluster_of, labels, conf, hits_out, min_hits
    ) -> None:
        """Batch the band verification: one sweep launch per query block
        against the union of the block's shortlisted clusters' members
        (per-query results are then restricted to that query's own
        shortlist, so labels/confidence are identical to the per-query
        host loop)."""
        from ..core.range_query import unpack_bitmap
        from ..index.sweep import sweep_bitmap

        t_lo, t_hi = self._band
        sizes = np.diff(self._offsets)

        def verify(s: int, e: int) -> None:
            ids = np.unique(top[s:e])
            n_cand = int(sizes[ids].sum())
            if n_cand == 0:
                return
            # the block shares one launch over the union of its
            # shortlisted clusters; low-overlap traffic would inflate a
            # query's verified set from |own shortlist| to |union|, so
            # split the block until the shared work stays within ~4x
            # the per-query shortlist totals
            if e - s > 8 and n_cand * (e - s) > 4 * int(sizes[top[s:e]].sum()):
                mid = (s + e) // 2
                verify(s, mid)
                verify(mid, e)
                return
            cand = np.concatenate([self.members(c) for c in ids])
            # bucket the candidate side to a power-of-two row count, no
            # smaller than the kernel db tile (the padding quantum the
            # engine applies anyway; zero rows + zero signatures are
            # exactly the capacity-slack shape its pad correction
            # handles) so the jitted launch compiles O(log n) shapes,
            # not one per shortlist union size — the serving hot path
            kw = dict(self.sweep_kw)
            # the query chunk clamps to the (power-of-two bucketed) leaf
            # size: a split-down leaf of 8 queries must not pad to a
            # full 256-row kernel pass
            bucket, kw["chunk"] = bucket_shape(
                len(cand), e - s,
                db_tile=kw.get("db_tile", 256),
                chunk=kw.get("chunk", 256),
                q_tile=kw.get("q_tile", 128),
            )
            db = np.zeros((bucket, self._data.shape[1]), dtype=np.float32)
            db[: len(cand)] = self._data[cand]
            db_sig = np.zeros((bucket, self._sigs.shape[1]), dtype=np.uint32)
            db_sig[: len(cand)] = self._sigs[cand]
            if (bucket, kw["chunk"]) not in self._seen_buckets:
                self._seen_buckets.add((bucket, kw["chunk"]))
                _metrics.counter("serve.bucket_compiles").inc()
            _metrics.counter("serve.verify_launches").inc()
            _metrics.counter("serve.candidates").inc(int(len(cand)))
            _, bm = sweep_bitmap(
                q[s:e], q_sig[s:e], db, db_sig,
                len(cand), self.eps, t_lo, t_hi, **kw,
            )
            hit = unpack_bitmap(bm, len(cand))
            cl = cluster_of[cand]
            for bi in range(e - s):
                i = s + bi
                sel = cl[hit[bi]]
                # restrict to the query's own shortlist (<= `shortlist`
                # ids) — isin over the few hits, never an O(n_clusters)
                # mask per query
                self._record(
                    i, sel[np.isin(sel, top[i])], labels, conf, hits_out, min_hits
                )

        for s in range(0, q.shape[0], 256):
            verify(s, min(s + 256, q.shape[0]))


def _unit_rows(x: np.ndarray) -> np.ndarray:
    x = np.ascontiguousarray(x, dtype=np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
