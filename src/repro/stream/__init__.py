"""``repro.stream`` — incremental LAF-DBSCAN: online ingest, cluster
maintenance, and a serving-grade assignment API.

* :class:`~repro.stream.ingest.StreamingLAF` — the batch driver:
  ``partial_fit(rows)`` appends to the index and maintains the clusters
  (new-vs-all range queries only; old points promote to core off the
  transposed hits), ``assign(queries)`` serves unseen vectors.
* :class:`~repro.stream.state.StreamingClusterState` — counts, core
  mask, growable union-find, and the min-core-neighbor border rule.
* :class:`~repro.stream.serve.ClusterIndex` — the immutable serving
  snapshot (centroid shortlist + band-verified assignment).
* :class:`~repro.stream.durability.DurableStream` — snapshot/WAL crash
  recovery and replica failover around a ``StreamingLAF``.
"""

from .durability import DurableStream, clone_replica, export_replica, import_replica  # noqa: F401
from .ingest import IngestReport, StreamingLAF  # noqa: F401
from .serve import AssignResult, ClusterIndex  # noqa: F401
from .state import StreamingClusterState  # noqa: F401
