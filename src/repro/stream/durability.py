"""Durable streaming plane: snapshot/restore, write-ahead log, failover.

``DurableStream`` wraps a :class:`~repro.stream.ingest.StreamingLAF`
with crash recovery:

* **Snapshots** ride ``repro.train.checkpoint`` (versioned manifest,
  per-array crc32, atomic ``tmp-`` → rename publish).  One snapshot is
  the *full serving replica*: the cluster state's capacity arrays + the
  union-find, the range backend's capacity buffers via the
  ``state_export`` protocol (exact rows / signed-RP signature+row
  slabs, append slack included), and the serve ``ClusterIndex``
  centroids.  Because every exported buffer is capacity-faithful, a
  restored replica re-enters the pre-crash jit compile caches — restore
  is **recompile-free** (laf-lint's restored-replica target pins this).
* **WAL** — every ``partial_fit`` / ``evict`` batch is appended to a
  length+crc framed log *before* it is applied, and the log rotates at
  each snapshot.  Recovery = newest valid snapshot + replay of the WAL
  tail; a torn final record (the un-fsynced tail of a mid-batch kill)
  fails its crc/length check and is dropped **deterministically**, so
  recovered labels/owners/counts are bit-identical to an uninterrupted
  run over the surviving prefix.
* **Corruption fallback** — a snapshot that fails its checksum verify
  is skipped and recovery falls back to the next older one; the WAL
  chain is replayed from whatever base was restored (per-record global
  sequence numbers make replay idempotent across bases).
* **Failover** — :func:`clone_replica` builds a read replica from the
  snapshot + WAL without touching the log; ``DurableStream.promote``
  replays whatever tail the dead primary wrote after the clone and
  takes over the log.  ``benchmarks/stream_bench.py --failover`` gates
  recovery time, WAL replay throughput, and snapshot overhead.

Layout (one directory per stream)::

    <root>/step_<seq>/        snapshots (repro.train.checkpoint dirs)
    <root>/wal_<seq>.log      records (seq', kind, npz payload, crc32)
                              appended after snapshot <seq>

Sequence numbers are global and monotonic: record k is the k-th
mutation the stream ever applied, snapshots are taken *at* a sequence
number, and ``wal_<s>.log`` holds records ``s+1 ..`` (until the next
rotation).  Replay filters on ``seq > base``, so it is correct even if
a crash lands between snapshot publish and log rotation.
"""

from __future__ import annotations

import io
import json
import os
import struct
import time
import zlib
from pathlib import Path
from typing import Optional

import numpy as np

from ..obs import get_logger, metrics as _metrics, rate_limited_warn, span as _span
from ..train.checkpoint import (
    CheckpointCorruptError,
    gc_checkpoints,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)
from .state import StreamingClusterState

__all__ = [
    "DurableStream",
    "WalWriter",
    "read_wal",
    "export_replica",
    "import_replica",
    "clone_replica",
    "KIND_INGEST",
    "KIND_EVICT",
]

_log = get_logger("stream.durability")

WAL_MAGIC = b"LAFW"
WAL_VERSION = 1
_REC_HDR = struct.Struct("<QBI")  # seq, kind, payload_len
_REC_CRC = struct.Struct("<I")

KIND_INGEST = 1
KIND_EVICT = 2

REPLICA_FORMAT = 1


def _npz_bytes(arrays: dict) -> bytes:
    bio = io.BytesIO()
    np.savez(bio, **arrays)
    return bio.getvalue()


def _npz_load(payload: bytes) -> dict:
    with np.load(io.BytesIO(payload)) as z:
        return {k: z[k] for k in z.files}


class WalWriter:
    """Append-only, length+crc framed record log (fsync per append by
    default — the durability boundary the mid-batch kill tests rely
    on: a record either fully lands or its torn tail is dropped)."""

    def __init__(self, path, *, fsync: bool = True):
        self.path = Path(path)
        self.fsync = fsync
        self._f = open(self.path, "wb")
        self._f.write(WAL_MAGIC + struct.pack("<I", WAL_VERSION))
        self._flush()

    def append(self, seq: int, kind: int, arrays: dict) -> int:
        payload = _npz_bytes(arrays)
        hdr = _REC_HDR.pack(seq, kind, len(payload))
        rec = hdr + payload + _REC_CRC.pack(zlib.crc32(hdr + payload))
        self._f.write(rec)
        self._flush()
        _metrics.counter("durability.wal_records").inc()
        _metrics.counter("durability.wal_bytes").inc(len(rec))
        return len(rec)

    def _flush(self) -> None:
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_wal(path):
    """Yield ``(seq, kind, arrays)`` records; stops **deterministically**
    at the first torn or corrupt record (short header, short payload,
    or crc mismatch) — the un-fsynced tail of a killed writer."""
    p = Path(path)
    if not p.exists():
        return
    raw = p.read_bytes()
    if len(raw) < 8 or raw[:4] != WAL_MAGIC:
        return
    off = 8
    while True:
        if off + _REC_HDR.size > len(raw):
            return
        hdr = raw[off : off + _REC_HDR.size]
        seq, kind, plen = _REC_HDR.unpack(hdr)
        end = off + _REC_HDR.size + plen + _REC_CRC.size
        if end > len(raw):
            return
        payload = raw[off + _REC_HDR.size : off + _REC_HDR.size + plen]
        (crc,) = _REC_CRC.unpack(raw[end - _REC_CRC.size : end])
        if crc != zlib.crc32(hdr + payload):
            return
        try:
            arrays = _npz_load(payload)
        except Exception:
            return
        yield seq, kind, arrays
        off = end


# -- replica export/import ---------------------------------------------------


def export_replica(stream, *, seq: int = 0) -> dict:
    """The full serving replica as a flat checkpoint pytree: cluster
    state arrays, backend capacity buffers, serve centroids, and a json
    meta leaf (format/config echo)."""
    state = stream.state.export_arrays()
    bk_state = stream.backend.state_export()
    serve = stream.snapshot()  # the ClusterIndex (cached per state version)
    meta = {
        "format": REPLICA_FORMAT,
        "seq": int(seq),
        "eps": float(stream.eps),
        "tau": int(stream.tau),
        "backend": stream.backend.name,
        "n_points": int(stream.state.n),
        "n_clusters": int(serve.n_clusters),
        "estimator_attached": stream.estimator is not None,
    }
    tree = {"meta": np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8).copy()}
    for k, v in state.items():
        tree[f"state.{k}"] = v
    for k, v in bk_state.items():
        tree[f"backend.{k}"] = v
    tree["centroids"] = serve.centroids
    return tree


def import_replica(stream, tree: dict) -> dict:
    """Load an ``export_replica`` tree into a *fresh, identically
    configured* stream (the factory owns code + config + estimator —
    only data travels through the snapshot).  Returns the meta dict."""
    meta = json.loads(np.asarray(tree["meta"], dtype=np.uint8).tobytes().decode())
    if meta["format"] != REPLICA_FORMAT:
        raise ValueError(f"replica format {meta['format']} != {REPLICA_FORMAT}")
    if meta["backend"] != stream.backend.name:
        raise ValueError(
            f"snapshot backend {meta['backend']!r} != stream backend "
            f"{stream.backend.name!r}"
        )
    if float(meta["eps"]) != stream.eps or int(meta["tau"]) != stream.tau:
        raise ValueError(
            f"snapshot operating point (eps={meta['eps']}, tau={meta['tau']}) != "
            f"stream (eps={stream.eps}, tau={stream.tau})"
        )
    stream.state = StreamingClusterState.import_arrays(
        {k.split(".", 1)[1]: v for k, v in tree.items() if k.startswith("state.")}
    )
    stream.backend.state_import(
        {k.split(".", 1)[1]: v for k, v in tree.items() if k.startswith("backend.")}
    )
    if meta.get("estimator_attached") and stream.estimator is None:
        rate_limited_warn(
            _log, "estimator_missing", "restored_without_estimator",
            n_points=meta["n_points"],
        )
    # plant the serving snapshot with the saved centroids so the replica
    # serves immediately without re-running the per-cluster mean pass
    from .serve import ClusterIndex

    stream._serve = ClusterIndex.from_stream(
        stream, centroids=np.asarray(tree["centroids"])
    )
    return meta


def _load_flat(root: Path, step: int) -> dict:
    """Restore one snapshot as the flat dict ``export_replica`` wrote
    (keys recovered from the manifest, values checksum-verified)."""
    manifest = json.loads((root / f"step_{step:012d}" / "manifest.json").read_text())
    keys = [p.strip("[]'\"") for p in manifest["paths"]]
    tree, _ = restore_checkpoint(root, step, template={k: 0 for k in keys})
    return tree


def _replay(stream, root: Path, after: int):
    """Apply every WAL record with ``seq > after`` in order; returns
    ``(last_seq, n_records, n_rows)``."""
    last, n_rec, n_rows = after, 0, 0
    files = sorted(
        root.glob("wal_*.log"), key=lambda f: int(f.stem.split("_")[1])
    )
    for f in files:
        for seq, kind, arrays in read_wal(f):
            if seq <= last:
                continue
            if kind == KIND_INGEST:
                rows = np.ascontiguousarray(arrays["rows"], dtype=np.float32)
                stream.partial_fit(rows)
                n_rows += rows.shape[0]
            elif kind == KIND_EVICT:
                stream.evict(np.asarray(arrays["idx"], dtype=np.int64))
            else:  # unknown kind: stop (a newer writer's record)
                rate_limited_warn(_log, "wal_kind", "wal_unknown_kind", kind=kind)
                return last, n_rec, n_rows
            last = seq
            n_rec += 1
    return last, n_rec, n_rows


def clone_replica(root, factory):
    """Build a **read replica**: newest valid snapshot (corrupt ones are
    skipped with a counter) + WAL replay, never touching the log.
    Returns ``(stream, seq, info)`` — hand ``(stream, seq)`` to
    :meth:`DurableStream.promote` after the primary dies."""
    root = Path(root)
    t0 = time.perf_counter()
    stream, base = None, 0
    for step in reversed(list_steps(root)):
        try:
            tree = _load_flat(root, step)
        except CheckpointCorruptError as e:
            _metrics.counter("durability.corrupt_snapshots").inc()
            rate_limited_warn(
                _log, "snap_corrupt", "snapshot_corrupt", step=step,
                error=type(e).__name__,
            )
            continue
        stream = factory()
        import_replica(stream, tree)
        base = step
        break
    if stream is None:
        stream = factory()
    t_snap = time.perf_counter()
    last, n_rec, n_rows = _replay(stream, root, base)
    t1 = time.perf_counter()
    _metrics.counter("durability.wal_replayed").inc(n_rec)
    info = {
        "snapshot_step": base,
        "seq": last,
        "wal_records": n_rec,
        "wal_rows": n_rows,
        "restore_s": t_snap - t0,
        "replay_s": t1 - t_snap,
        "recovery_s": t1 - t0,
    }
    return stream, last, info


class DurableStream:
    """A :class:`StreamingLAF` with write-ahead logging + snapshots.

    Use the constructor for a *fresh* stream directory (it opens a new
    log); use :meth:`recover` to resume after a crash and
    :meth:`promote` to take over from a cloned read replica.  Ingest
    and evict delegate to the wrapped stream after logging, so an
    uninterrupted ``DurableStream`` is label-identical to the bare
    stream fed the same batches.
    """

    def __init__(
        self,
        stream,
        root,
        *,
        snapshot_every: Optional[int] = None,
        keep: int = 3,
        fsync: bool = True,
        seq: int = 0,
    ):
        self.stream = stream
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        cfg = getattr(stream, "config", None)
        self.snapshot_every = (
            int(getattr(cfg, "snapshot_every", 8))
            if snapshot_every is None
            else int(snapshot_every)
        )
        self.keep = int(keep)
        self.fsync = bool(fsync)
        self.seq = int(seq)
        self.recovery_info: Optional[dict] = None
        self._wal = WalWriter(self.root / f"wal_{self.seq:012d}.log", fsync=fsync)

    # -- recovery / failover ----------------------------------------------
    @classmethod
    def recover(cls, root, factory, **kw) -> "DurableStream":
        """Resume after process death: snapshot + WAL replay, then an
        immediate snapshot to establish a clean base for the new log."""
        stream, seq, info = clone_replica(root, factory)
        d = cls(stream, root, seq=seq, **kw)
        d.recovery_info = info
        d.snapshot()
        return d

    @classmethod
    def promote(cls, stream, root, seq: int, **kw) -> "DurableStream":
        """Promote a read replica cloned at ``seq``: replay the WAL tail
        the dead primary wrote after the clone, then take over the log."""
        root = Path(root)
        t0 = time.perf_counter()
        last, n_rec, n_rows = _replay(stream, root, seq)
        _metrics.counter("durability.wal_replayed").inc(n_rec)
        d = cls(stream, root, seq=last, **kw)
        d.recovery_info = {
            "promoted_from": seq,
            "seq": last,
            "wal_records": n_rec,
            "wal_rows": n_rows,
            "recovery_s": time.perf_counter() - t0,
        }
        d.snapshot()
        return d

    # -- logged mutations ---------------------------------------------------
    def partial_fit(self, batch: np.ndarray):
        batch = np.ascontiguousarray(batch, dtype=np.float32)
        # write-ahead: the record lands (fsynced) before the mutation, so
        # a crash mid-apply replays it and a crash mid-write drops the
        # torn tail — either way recovery is deterministic
        self._wal.append(self.seq + 1, KIND_INGEST, {"rows": batch})
        rep = self.stream.partial_fit(batch)
        self.seq += 1
        self._maybe_snapshot()
        return rep

    def evict(self, idx: np.ndarray) -> bool:
        idx = np.asarray(idx, dtype=np.int64)
        self._wal.append(self.seq + 1, KIND_EVICT, {"idx": idx})
        out = self.stream.evict(idx)
        self.seq += 1
        self._maybe_snapshot()
        return out

    def _maybe_snapshot(self) -> None:
        if self.snapshot_every and self.seq % self.snapshot_every == 0:
            self.snapshot()

    def snapshot(self) -> Path:
        """Publish a snapshot at the current sequence number, rotate the
        log, and GC old snapshots + the WAL files they cover."""
        with _span("durability.snapshot", seq=self.seq, n=self.stream.state.n):
            tree = export_replica(self.stream, seq=self.seq)
            path = save_checkpoint(self.root, self.seq, tree, fsync=self.fsync)
            self._wal.close()
            self._wal = WalWriter(
                self.root / f"wal_{self.seq:012d}.log", fsync=self.fsync
            )
            gc_checkpoints(self.root, self.keep)
            steps = list_steps(self.root)
            if steps:
                # wal_<s>.log holds records s+1..<next snapshot>, so any
                # file older than the oldest kept snapshot is fully
                # covered by that snapshot and can go
                oldest = steps[0]
                for f in self.root.glob("wal_*.log"):
                    if int(f.stem.split("_")[1]) < oldest and f != self._wal.path:
                        f.unlink()
        _metrics.counter("durability.snapshots").inc()
        return path

    def close(self) -> None:
        self._wal.close()

    # -- delegation ---------------------------------------------------------
    def assign(self, queries: np.ndarray, **kw):
        return self.stream.assign(queries, **kw)

    def labels(self) -> np.ndarray:
        return self.stream.labels()

    def serve_snapshot(self):
        """The serving :class:`~repro.stream.serve.ClusterIndex` (the
        wrapped stream's ``snapshot()`` — renamed here because
        ``DurableStream.snapshot`` is the durable one)."""
        return self.stream.snapshot()

    @property
    def state(self):
        return self.stream.state

    @property
    def backend(self):
        return self.stream.backend

    @property
    def n_points(self) -> int:
        return self.stream.n_points

    @property
    def n_clusters(self) -> int:
        return self.stream.n_clusters
