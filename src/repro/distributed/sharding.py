"""PartitionSpec rules for params, optimizer state and activations.

Default parameter rule (FSDP × TP, the 1000+-node-friendly layout):
  * last dim        -> "model"            (tensor parallel)
  * second-to-last  -> ("pod", "data")    (fully-sharded data parallel;
                                           "pod" only on multi-pod meshes)
  * leading stack/expert axes -> replicated (scanned layer axis) unless
    the axis divides the model axis exactly and the tensor is an MoE
    expert stack (expert parallelism is explored in §Perf instead).
A dim is sharded only when its size divides the mesh-axis size — any
remainder falls back to replication for that dim (never a compile
failure, at worst a wider collective recorded by the roofline pass).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "named",
    "replicated",
    "param_sharding_rule",
    "tree_param_shardings",
    "tree_replicated",
    "axis_size",
    "data_axes",
]


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh.shape[axes])
    return int(np.prod([mesh.shape[a] for a in axes]))


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def data_axes(mesh: Mesh) -> tuple:
    """The mesh axes that carry data parallelism, as a tuple usable both
    as a PartitionSpec entry and with :func:`axis_size`.  The single
    definition of "which axes shard the batch/database" — the step
    builders, the index plane, and the param rule all derive from here
    instead of re-spelling the pod special case."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


_dp_axes = data_axes  # back-compat spelling (pre-index-plane callers)


def param_sharding_rule(mesh: Mesh, shape: Sequence[int]) -> NamedSharding:
    """The default FSDP×TP rule described in the module docstring."""
    ndim = len(shape)
    spec: list = [None] * ndim
    dp = data_axes(mesh)
    if ndim >= 1 and shape[-1] % axis_size(mesh, "model") == 0 and shape[-1] >= axis_size(mesh, "model"):
        # 1-D tensors stay replicated (tiny norms/biases)
        if ndim >= 2:
            spec[-1] = "model"
    if ndim >= 2:
        dp_size = axis_size(mesh, dp)
        if shape[-2] % dp_size == 0 and shape[-2] >= dp_size:
            spec[-2] = dp if len(dp) > 1 else dp[0]
    return NamedSharding(mesh, P(*spec))


def tree_param_shardings(mesh: Mesh, abstract_params: Any):
    """Map the rule over an eval_shape'd param pytree."""
    return jax.tree_util.tree_map(
        lambda leaf: param_sharding_rule(mesh, leaf.shape), abstract_params
    )


def tree_replicated(mesh: Mesh, abstract_tree: Any):
    return jax.tree_util.tree_map(lambda _: replicated(mesh), abstract_tree)
