"""Device-sharded index plane: the fused ``hamming_filter`` tile on any
mesh size.

The database rows and their packed sign-signature table are sharded
*identically* over the mesh's data axes (sDBSCAN's observation: the
random-projection summary is small enough to live with the points it
summarizes), so every range query runs shard-locally — KNN-DBSCAN's
rule that distributed high-dimensional DBSCAN lives or dies on keeping
neighborhood queries next to their data shard.  Inside each shard the
existing single-device machinery is reused unchanged: the ops wrapper
pads the local block to the kernel tile multiple and applies the
dual-threshold padded-row correction per shard.  Only per-shard results
cross the network —

* counts: one ``psum`` of (nq,) int32 partial counts;
* bitmaps: an all-gather of the (nq, n_local/32) packed uint32 words
  (the shard axis concatenates on the word dim, so the gathered array
  *is* the global bitmap);
* marginals: ``psum`` of per-query counts + the per-row partial counts
  left sharded in place —

never the (nq, n) boolean hit matrix, the database, or the signature
table.  Plane-level padding (to a shard multiple of rows) uses zero
rows with zero signatures, exactly the shape the kernel wrappers'
``_pad_col_hits`` correction was built for, so non-shard-multiple
databases stay exact — including the eps > 1 corner where zero rows
pass the dot test.

A 1-device mesh degenerates to the plain wrapper call (the ``psum`` and
gather are trivial), which is what lets ``index_device="auto"`` stop
special-casing single-device lowerings.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..index.signatures import shard_signatures, unpack_bits
from ..obs import metrics as _metrics
from ..kernels.hamming_filter.ops import (
    DEFAULT_DB_TILE,
    DEFAULT_Q_TILE,
    _pad_col_hits,
    _tail_word_mask,
    default_interpret,
    hamming_filter_bitmap,
    hamming_filter_count,
)
from .sharding import axis_size, data_axes

__all__ = [
    "ShardPlan",
    "shard_plan",
    "shard_database",
    "sharded_hamming_count",
    "sharded_hamming_bitmap",
    "sharded_band_marginals",
    "sharded_sweep_launch",
    "sharded_sweep_marginals",
    "sharded_cluster_labels",
]

I32 = jnp.int32


def _count_collectives(kind: str, nq: int, n_chunks: int, n_shards: int,
                       words: int = 0, pipelined: bool = False) -> None:
    """Analytic per-call collective accounting (the traced program runs
    the psums, so they are counted here at dispatch, from the launch
    shape): each chunk's count psum moves ``chunk * 4`` bytes per shard
    hop, bitmap gathers move each shard's word block to every peer."""
    if not _metrics.enabled() or n_shards <= 1:
        return
    chunk = nq // max(n_chunks, 1)
    _metrics.counter("plane.psum.calls").inc(n_chunks)
    _metrics.counter("plane.psum.bytes").inc(n_chunks * chunk * 4)
    if kind == "bitmap":
        _metrics.counter("plane.gather.calls").inc(1)
        _metrics.counter("plane.gather.bytes").inc(nq * words * 4)
    _metrics.counter(
        "plane.chunks.pipelined" if pipelined else "plane.chunks.serialized"
    ).inc(n_chunks)


@dataclass(frozen=True)
class ShardPlan:
    """Row layout of one database over one mesh.

    ``n_padded`` is ``n`` rounded up to ``32 * n_shards`` so every shard
    holds the same number of rows *and* its packed bitmap rows are
    word-aligned (a shard's words concatenate into the global bitmap
    without bit shifting).
    """

    axes: Tuple[str, ...]
    n_shards: int
    n: int
    n_padded: int

    @property
    def n_local(self) -> int:
        return self.n_padded // self.n_shards

    @property
    def n_pad(self) -> int:
        return self.n_padded - self.n


def shard_plan(mesh: Mesh, n: int, axes=None, *, tile: int = 32) -> ShardPlan:
    """Row plan for an ``n``-row database sharded over ``axes`` (default:
    the mesh's data axes).  ``tile`` (a multiple of 32, e.g. the kernel
    db tile) additionally aligns every shard's row count to that
    multiple, so shard-local kernel calls never re-pad per launch —
    what the sweep engine's one-launch scans rely on."""
    axes = data_axes(mesh) if axes is None else tuple(axes)
    n_shards = axis_size(mesh, axes)
    if tile % 32:
        raise ValueError(f"tile must be a multiple of 32, got {tile}")
    mult = max(32, tile) * n_shards
    return ShardPlan(axes, n_shards, n, -(-n // mult) * mult)


def _pad_rows_to(x, n_padded: int):
    pad = n_padded - x.shape[0]
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def shard_database(mesh: Mesh, data, sigs, axes=None, *, tile: int = 32):
    """Co-shard a database and its packed signature table.

    Returns ``(db, db_sig, plan)`` where both arrays are padded to
    ``plan.n_padded`` zero rows / zero signature words and placed with
    ``P(axes, None)`` — one ``device_put`` each at fit time, so queries
    never move the table again.  ``tile`` aligns every shard to the
    kernel db tile (see :func:`shard_plan`).
    """
    plan = shard_plan(mesh, data.shape[0], axes, tile=tile)
    spec = P(plan.axes, None)
    db = jax.device_put(
        _pad_rows_to(jnp.asarray(data, jnp.float32), plan.n_padded),
        NamedSharding(mesh, spec),
    )
    db_sig = shard_signatures(mesh, sigs, spec, n_padded=plan.n_padded)
    return db, db_sig, plan


@functools.lru_cache(maxsize=None)
def _build_plane_fn(mesh: Mesh, axes, kind: str, q_tile: int, db_tile: int, interpret: bool):
    """shard_map'd evaluator, cached per (mesh, axes, variant, tiles).

    eps and the band thresholds ride in as traced operands (``eps``
    f32[1], ``band`` i32[2]) so eps sweeps never rebuild or recompile.
    """
    # body only runs on an lru_cache miss — i.e. a genuine plane rebuild
    _metrics.counter("plane.builds").inc()
    rep = P(None, None)
    row_sharded = P(axes, None)

    if kind == "count":

        def body(qc, db, qs, dbs, eps, band):
            c = hamming_filter_count(
                qc, db, qs, dbs, eps[0], band[1], t_lo=band[0],
                q_tile=q_tile, db_tile=db_tile, interpret=interpret,
            )
            return jax.lax.psum(c, axes)

        out_specs = P()
    elif kind == "bitmap":

        def body(qc, db, qs, dbs, eps, band):
            c, bm = hamming_filter_bitmap(
                qc, db, qs, dbs, eps[0], band[1], t_lo=band[0],
                q_tile=q_tile, db_tile=db_tile, interpret=interpret,
            )
            return jax.lax.psum(c, axes), bm

        out_specs = (P(), P(None, axes))
    else:  # marginals

        def body(qc, db, qs, dbs, eps, band):
            _, bm = hamming_filter_bitmap(
                qc, db, qs, dbs, eps[0], band[1], t_lo=band[0],
                q_tile=q_tile, db_tile=db_tile, interpret=interpret,
            )
            # all-zero db rows are padding by construction (unit-norm
            # data never has a zero row): whatever their signatures say
            # — zero words from plane padding, all-ones from the
            # lowering's sign(0) packing — they must never count
            hit = unpack_bits(bm, db.shape[0]) & jnp.any(db != 0, axis=1)[None, :]
            return (
                jax.lax.psum(hit.sum(axis=1, dtype=I32), axes),
                hit.sum(axis=0, dtype=I32),
            )

        out_specs = (P(), P(axes))

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(rep, row_sharded, rep, row_sharded, P(None), P(None)),
        out_specs=out_specs,
        check_rep=False,
    )


def _prep(q, db, q_sig, db_sig, eps, t_lo, t_hi, mesh, axes, interpret):
    plan = shard_plan(mesh, db.shape[0], axes)
    if interpret is None:
        interpret = default_interpret()
    db = _pad_rows_to(jnp.asarray(db), plan.n_padded)
    db_sig = _pad_rows_to(jnp.asarray(db_sig, jnp.uint32), plan.n_padded)
    # eps rides as a traced (1,) operand (the wrappers derive the dot
    # threshold themselves) so eps sweeps never rebuild the plane
    eps_op = jnp.asarray([eps], jnp.float32)
    band = jnp.stack([jnp.asarray(t_lo, I32), jnp.asarray(t_hi, I32)])
    return plan, db, db_sig, eps_op, band, interpret


def sharded_hamming_count(
    q,
    db,
    q_sig,
    db_sig,
    eps,
    t_hi,
    *,
    mesh: Mesh,
    t_lo=-1,
    axes=None,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: Optional[bool] = None,
):
    """(nq,) int32 global band-contract counts; queries replicated, db +
    signatures row-sharded, one psum on the wire."""
    plan, db, db_sig, eps_op, band, interpret = _prep(
        q, db, q_sig, db_sig, eps, t_lo, t_hi, mesh, axes, interpret
    )
    f = _build_plane_fn(mesh, plan.axes, "count", q_tile, db_tile, interpret)
    _count_collectives("count", q.shape[0], 1, plan.n_shards)
    counts = f(jnp.asarray(q), db, jnp.asarray(q_sig, jnp.uint32), db_sig, eps_op, band)
    if plan.n_pad:
        counts = counts - _pad_col_hits(jnp.asarray(q_sig, jnp.uint32), eps, t_lo, t_hi, plan.n_pad)
    return counts


def sharded_hamming_bitmap(
    q,
    db,
    q_sig,
    db_sig,
    eps,
    t_hi,
    *,
    mesh: Mesh,
    t_lo=-1,
    axes=None,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: Optional[bool] = None,
):
    """(counts, packed adjacency) with plane-pad bits cleared.

    Each shard emits its word-aligned (nq, n_local/32) block; the
    gather concatenates blocks on the word axis into the global
    (nq, ceil(n/32)) bitmap — identical to the single-device wrapper's
    output on the same inputs.
    """
    nd = db.shape[0]
    plan, db, db_sig, eps_op, band, interpret = _prep(
        q, db, q_sig, db_sig, eps, t_lo, t_hi, mesh, axes, interpret
    )
    f = _build_plane_fn(mesh, plan.axes, "bitmap", q_tile, db_tile, interpret)
    _count_collectives("bitmap", q.shape[0], 1, plan.n_shards,
                       words=plan.n_padded // 32)
    q_sig = jnp.asarray(q_sig, jnp.uint32)
    counts, bitmap = f(jnp.asarray(q), db, q_sig, db_sig, eps_op, band)
    if plan.n_pad:
        counts = counts - _pad_col_hits(q_sig, eps, t_lo, t_hi, plan.n_pad)
        bitmap = bitmap & _tail_word_mask(bitmap.shape[1], nd)[None, :]
    return counts, bitmap[:, : -(-nd // 32)]


def sharded_band_marginals(
    q,
    db,
    q_sig,
    db_sig,
    eps,
    t_hi,
    *,
    mesh: Mesh,
    t_lo=-1,
    axes=None,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: Optional[bool] = None,
):
    """Both marginals of the hit matrix without gathering it: per-query
    counts (replicated, psum'd) and per-db-row partial counts (left
    sharded ``P(axes)`` — the layout the clustering lowering keeps its
    partial-neighbor accumulator in).  All-zero db rows never count, so
    callers that pad with zero rows need no correction here.
    """
    nd = db.shape[0]
    plan, db, db_sig, eps_op, band, interpret = _prep(
        q, db, q_sig, db_sig, eps, t_lo, t_hi, mesh, axes, interpret
    )
    f = _build_plane_fn(mesh, plan.axes, "marginals", q_tile, db_tile, interpret)
    _count_collectives("count", q.shape[0], 1, plan.n_shards)
    counts, partial = f(
        jnp.asarray(q), db, jnp.asarray(q_sig, jnp.uint32), db_sig, eps_op, band
    )
    return counts, partial[:nd] if plan.n_pad else partial


# ---------------------------------------------------------------------------
# device-resident sweeps: all chunks of a launch inside one shard_map,
# software-pipelined so chunk k's psum overlaps chunk k+1's popcount
# ---------------------------------------------------------------------------


def _pipeline(local, combine, items, depth: int):
    """Run ``combine(local(item))`` per item as a lax.scan.

    ``depth >= 2`` double-buffers: iteration *k* computes
    ``local(items[k])`` while combining ``local(items[k-1])`` — the two
    have no data dependence, so the compiler is free to overlap the
    previous chunk's collective with the next chunk's shard-local
    popcount+verify.  ``depth == 1`` keeps the serialized
    compute→combine chain per chunk (the parity/latency baseline).
    Items is a pytree of stacked leading-axis operands; local may
    return a pytree, combine maps local results to outputs.
    """
    n_items = jax.tree_util.tree_leaves(items)[0].shape[0]
    if depth >= 2 and n_items > 1:
        head = jax.tree_util.tree_map(lambda x: x[0], items)
        tail = jax.tree_util.tree_map(lambda x: x[1:], items)

        def step(carry, xs):
            return local(xs), combine(carry)

        last, outs = jax.lax.scan(step, local(head), tail)
        return jax.tree_util.tree_map(
            lambda o, l: jnp.concatenate([o, l[None]], axis=0), outs, combine(last)
        )
    return jax.lax.map(lambda xs: combine(local(xs)), items)


@functools.lru_cache(maxsize=None)
def _build_sweep_plane_fn(
    mesh: Mesh, axes, kind: str, chunk: int, q_tile: int, db_tile: int,
    interpret: bool, depth: int, telemetry: bool = False,
):
    """One-launch sharded sweep, cached per (mesh, axes, variant, tiles,
    chunk, pipeline depth).  The launch's query rows arrive stacked
    ``(cpl * chunk, ...)`` replicated; the db + signature table arrive
    row-sharded (the plane arrays from ``shard_database``).

    ``telemetry`` appends a replicated ``(cpl, 3)`` s32 output of
    per-chunk ``[accept, band, reject]`` kernel-tile occupancy, psum'd
    across shards per chunk (an s32 triple on the wire — it rides the
    same double-buffered slot as the count psum, so the pipeline
    overlap is unchanged)."""
    _metrics.counter("plane.builds").inc()
    rep = P(None, None)
    row_sharded = P(axes, None)
    kw = dict(q_tile=q_tile, db_tile=db_tile, interpret=interpret)

    def _tile_sum(s):
        return s.reshape(-1, 3).sum(axis=0).astype(I32)

    if kind == "count":

        def body(q, qs, db, dbs, eps, band):
            cpl = q.shape[0] // chunk
            items = (q.reshape(cpl, chunk, -1), qs.reshape(cpl, chunk, -1))

            def local(xs):
                out = hamming_filter_count(
                    xs[0], db, xs[1], dbs, eps[0], band[1], t_lo=band[0],
                    return_stats=telemetry, **kw
                )
                return (out[0], _tile_sum(out[1])) if telemetry else out

            if telemetry:
                outs, stats = _pipeline(
                    local,
                    lambda cs: (jax.lax.psum(cs[0], axes),
                                jax.lax.psum(cs[1], axes)),
                    items, depth,
                )
                return outs.reshape(cpl * chunk), stats
            outs = _pipeline(local, lambda c: jax.lax.psum(c, axes), items, depth)
            return outs.reshape(cpl * chunk)

        out_specs = (P(None), P(None, None)) if telemetry else P(None)
    else:  # bitmap

        def body(q, qs, db, dbs, eps, band):
            cpl = q.shape[0] // chunk
            items = (q.reshape(cpl, chunk, -1), qs.reshape(cpl, chunk, -1))

            def local(xs):
                out = hamming_filter_bitmap(
                    xs[0], db, xs[1], dbs, eps[0], band[1], t_lo=band[0],
                    return_stats=telemetry, **kw
                )
                if telemetry:
                    return out[0], out[1], _tile_sum(out[2])
                return out

            # only the per-chunk count psum (and the s32 occupancy
            # triple under telemetry) crosses the network; the
            # word-aligned bitmap blocks stay shard-local until the
            # out_specs gather at launch end
            if telemetry:
                outs_c, outs_bm, stats = _pipeline(
                    local,
                    lambda cbs: (jax.lax.psum(cbs[0], axes), cbs[1],
                                 jax.lax.psum(cbs[2], axes)),
                    items, depth,
                )
                return (
                    outs_c.reshape(cpl * chunk),
                    outs_bm.reshape(cpl * chunk, outs_bm.shape[-1]),
                    stats,
                )
            outs_c, outs_bm = _pipeline(
                local, lambda cb: (jax.lax.psum(cb[0], axes), cb[1]), items, depth
            )
            return (
                outs_c.reshape(cpl * chunk),
                outs_bm.reshape(cpl * chunk, outs_bm.shape[-1]),
            )

        out_specs = (
            (P(None), P(None, axes), P(None, None))
            if telemetry
            else (P(None), P(None, axes))
        )

    # jit the shard_map'd sweep so the launch program (the whole chunk
    # scan) is traced once per shape and every later sweep is a single
    # cached dispatch — eager shard_map re-traces per call, which would
    # cost more than the sweep itself
    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(rep, rep, row_sharded, row_sharded, P(None), P(None)),
            out_specs=out_specs,
            check_rep=False,
        )
    )


def sharded_sweep_launch(
    kind: str,
    q,
    q_sig,
    db,
    db_sig,
    eps_op,
    band_op,
    *,
    mesh: Mesh,
    axes,
    chunk: int,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool = False,
    depth: int = 2,
    n: int,
    telemetry: bool = False,
):
    """One launch of the device-resident sharded sweep (driven by
    :mod:`repro.index.sweep`): ``(result, n_pad)`` where ``n_pad`` is
    the plane's zero-row column slack the driver corrects once per
    sweep.  ``db``/``db_sig`` are the plane-sharded arrays; each shard's
    rows should be db-tile aligned (``shard_database(..., tile=)``) so
    the scanned kernel calls never re-pad inside the loop.  With
    ``telemetry`` the result tuple grows a trailing replicated
    ``(cpl, 3)`` per-chunk occupancy array (count results become a
    2-tuple)."""
    axes = data_axes(mesh) if axes is None else tuple(axes)
    f = _build_sweep_plane_fn(
        mesh, axes, kind, chunk, q_tile, db_tile, interpret, depth,
        bool(telemetry),
    )
    _count_collectives(
        kind, q.shape[0], q.shape[0] // chunk, axis_size(mesh, axes),
        words=db.shape[0] // 32, pipelined=depth >= 2,
    )
    out = f(q, jnp.asarray(q_sig, jnp.uint32), db, db_sig, eps_op, band_op)
    return out, db.shape[0] - n


def sharded_sweep_marginals(
    qs,
    db,
    q_sigs,
    db_sig,
    eps,
    t_hi,
    *,
    mesh: Mesh,
    t_lo=-1,
    axes=None,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: Optional[bool] = None,
    depth: int = 2,
):
    """One-launch, software-pipelined form of
    :func:`sharded_band_marginals` over pre-chunked frontiers.

    ``qs``/``q_sigs`` are the whole frontier stacked ``(n_chunks, C,
    ·)`` — signatures packed once per sweep, not once per chunk.  The
    per-chunk count psum is double-buffered against the next chunk's
    shard-local popcount+verify (``depth=2``); per-row partials
    accumulate in the scan carry and stay sharded ``P(axes)``.  Returns
    ``(counts (n_chunks, C) replicated, partial (n,) sharded)``.
    """
    nd = db.shape[0]
    plan = shard_plan(mesh, nd, axes, tile=db_tile)
    if interpret is None:
        interpret = default_interpret()
    db = _pad_rows_to(jnp.asarray(db), plan.n_padded)
    db_sig = _pad_rows_to(jnp.asarray(db_sig, jnp.uint32), plan.n_padded)
    eps_op = jnp.asarray([eps], jnp.float32)
    band = jnp.stack([jnp.asarray(t_lo, I32), jnp.asarray(t_hi, I32)])
    f = _build_sweep_marginals_fn(
        mesh, plan.axes, q_tile, db_tile, interpret, depth
    )
    qs = jnp.asarray(qs)
    _count_collectives(
        "count", qs.shape[0] * qs.shape[1], qs.shape[0],
        plan.n_shards, pipelined=depth >= 2,
    )
    counts, partial = f(
        qs, jnp.asarray(q_sigs, jnp.uint32), db, db_sig, eps_op, band
    )
    return counts, partial[:nd] if plan.n_pad else partial


@functools.lru_cache(maxsize=None)
def _build_sweep_marginals_fn(
    mesh: Mesh, axes, q_tile: int, db_tile: int, interpret: bool, depth: int
):
    _metrics.counter("plane.builds").inc()
    kw = dict(q_tile=q_tile, db_tile=db_tile, interpret=interpret)

    def body(qs, qss, db, dbs, eps, band):
        # all-zero db rows are padding by construction (unit-norm data
        # never has a zero row) — computed once per sweep, masked per
        # chunk (see sharded_band_marginals for why signatures alone
        # cannot be trusted on pad rows)
        db_valid = jnp.any(db != 0, axis=1)

        def local(xs):
            _, bm = hamming_filter_bitmap(
                xs[0], db, xs[1], dbs, eps[0], band[1], t_lo=band[0], **kw
            )
            hit = unpack_bits(bm, db.shape[0]) & db_valid[None, :]
            return hit.sum(axis=1, dtype=I32), hit.sum(axis=0, dtype=I32)

        if depth >= 2 and qs.shape[0] > 1:
            c0, p0 = local((qs[0], qss[0]))

            def step(carry, xs):
                c_prev, p_acc = carry
                c_k, p_k = local(xs)
                # psum of the *previous* chunk's per-query counts: no
                # data dependence on this chunk's popcount+verify, so
                # the collective and the compute overlap
                return (c_k, p_acc + p_k), jax.lax.psum(c_prev, axes)

            (c_last, partial), counts = jax.lax.scan(
                step, (c0, p0), (qs[1:], qss[1:])
            )
            counts = jnp.concatenate(
                [counts, jax.lax.psum(c_last, axes)[None]], axis=0
            )
        else:

            def step(p_acc, xs):
                c_k, p_k = local(xs)
                return p_acc + p_k, jax.lax.psum(c_k, axes)

            partial, counts = jax.lax.scan(
                step, jnp.zeros((db.shape[0],), I32), (qs, qss)
            )
        return counts, partial

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(None, None, None), P(None, None, None),
                P(axes, None), P(axes, None), P(None), P(None),
            ),
            out_specs=(P(None, None), P(axes)),
            check_rep=False,
        )
    )


# ---------------------------------------------------------------------------
# device-resident clustering: the packed cluster fixpoint on the plane —
# per round only s32 label vectors ride collectives (pmin of the row
# minima, one counts psum up front); the packed words stay shard-local
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_cluster_plane_fn(
    mesh: Mesh, axes, n: int, max_iters: int,
    row_tile: int, word_tile: int, interpret: bool,
    telemetry: bool = False,
):
    """shard_map'd one-launch cluster pass, cached per (mesh, axes, n,
    tiles).  The slab arrives with its words sharded ``P(None, axes)``
    (the sweep plane's bitmap layout: shard k's words are the columns of
    shard k's database rows); ``rows`` and ``tau`` ride replicated.
    With ``telemetry`` the fixpoint's four per-round s32 vectors come
    back replicated (``P(None)``) — the shard-wins marginal is psum'd
    inside the round, so the outputs are replication-clean (LAF104).
    """
    _metrics.counter("plane.builds").inc()
    from ..kernels.label_prop import packed_cluster_fixpoint

    ax = axes if isinstance(axes, tuple) else (axes,)
    n_shards = axis_size(mesh, ax)

    def body(bitmap, rows, tau):
        cap_loc = bitmap.shape[1] * 32
        # flattened shard index in P(axes) concatenation order (major
        # axis first) -> this shard's global column offset
        idx = jnp.int32(0)
        for a in ax:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return packed_cluster_fixpoint(
            bitmap, rows, tau[0], idx * cap_loc,
            n=n, cap=cap_loc * n_shards, max_iters=max_iters,
            row_tile=row_tile, word_tile=word_tile, interpret=interpret,
            axes=ax, telemetry=telemetry,
        )

    out_specs = (P(None), P(axes), P(axes), P(None), P(None))
    if telemetry:
        out_specs = out_specs + ((P(None),) * 4,)
    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(P(None, axes), P(None), P(None)),
            out_specs=out_specs,
            check_rep=False,
        )
    )


def sharded_cluster_labels(
    bitmap,
    rows,
    tau,
    *,
    mesh: Mesh,
    axes,
    n: int,
    max_iters: int = 64,
    row_tile: int = 256,
    word_tile: int = 64,
    interpret=None,
    telemetry=None,
):
    """One-launch cluster pass over a column-sharded packed slab.

    ``bitmap`` is the (R, W) device slab from
    :func:`repro.index.sweep.sweep_bitmap_device` under ``mesh=`` —
    words sharded ``P(None, axes)``, tail bits past ``n`` cleared —
    and ``rows`` the (R,) database indices of the slab rows (sentinel
    >= n on padding).  Same contract as
    :func:`repro.kernels.label_prop.packed_cluster_labels`: returns
    device arrays ``(labels, owner, col_sum, counts, rounds)`` with no
    host sync; ``owner``/``col_sum`` come back column-sharded and
    reassemble on fetch.  ``telemetry`` (default: the ``repro.obs``
    device switch) appends the replicated per-round tuple.
    """
    if interpret is None:
        interpret = default_interpret()
    if telemetry is None:
        from ..obs import device_enabled

        telemetry = device_enabled()
    axes = tuple(axes) if isinstance(axes, (tuple, list)) else (axes,)
    w_loc = bitmap.shape[1] // axis_size(mesh, axes)
    # tiles must divide the shard-local slab exactly — padding local
    # words would shift every later shard's global column indices
    row_tile = math.gcd(bitmap.shape[0], row_tile)
    word_tile = math.gcd(w_loc, word_tile)
    _metrics.counter("labelprop.launches").inc()
    f = _build_cluster_plane_fn(
        mesh, axes, n, max_iters, row_tile, word_tile, interpret,
        bool(telemetry),
    )
    return f(
        bitmap,
        jnp.asarray(rows, I32),
        jnp.asarray([tau], I32),
    )
