from .sharding import (  # noqa: F401
    param_sharding_rule,
    tree_param_shardings,
    replicated,
    named,
)
