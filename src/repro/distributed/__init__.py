from .sharding import (  # noqa: F401
    axis_size,
    data_axes,
    param_sharding_rule,
    tree_param_shardings,
    replicated,
    named,
)
